"""CacheFDB — the read-through dissemination cache facade.

The paper's workflow is write-once read-many-millions (§1): the archive
side is one I/O-server burst, the read side is every downstream consumer
asking for the same freshly produced fields at once.  This facade makes
that fan-out cheap while staying a drop-in :class:`~repro.core.FDBClient`
tier (``{"type": "cache", "inner": {...}}`` in
:func:`~repro.core.config.build_fdb` — it composes above SelectFDB,
CodecFDB, AsyncFDB or RemoteFDB unchanged):

- **read-through**: ``retrieve``/``retrieve_batch`` serve payload bytes
  from the consistent-hash sharded store (:mod:`repro.cache.shard`) and
  fall through to the inner client on a miss, filling on the way back;
- **single-flight**: concurrent misses of one key elect a leader that pays
  ONE inner round; followers block on its flight
  (:mod:`repro.cache.singleflight`).  Partial ``retrieve_many`` requests
  coalesce the same way at the request-resolution level, so N identical
  MARS requests cost one catalogue listing;
- **write-path invalidation**: ``archive``/``archive_batch``/
  ``archive_fields`` invalidate exactly the touched keys, ``wipe`` drops
  the touched datasets (the granularity :class:`~repro.core.WipeReport`
  names); generation counters refuse fills that raced a write, so stale
  bytes are never resurrected;
- **async write ordering**: over a deferred-visibility inner (AsyncFDB, a
  remote server still coalescing), a read of a key this client archived
  but has not flushed would race the background writer.  The facade keeps
  a *dirty set* and :meth:`read_barrier` — the explicit ordering hook —
  flushes the inner tree before serving any read that touches a dirty key,
  so read-your-writes holds without callers sprinkling ``flush()``.

Correctness bar: a cached retrieve is byte-for-byte the backend retrieve —
the cache stores wire payloads, so lazy codec'd
:class:`~repro.core.codec.DecodedFieldSet` reads decode identically from a
hit — and reads after ``wipe``/re-archive never serve stale chunks.

Telemetry: hits/misses/coalesced waits/evictions are spans
(``cache.hit``/``cache.miss``/``cache.coalesced_wait``/``cache.evict``)
and IOStats ops on a dedicated ``"cache"`` sink.  Bytes served from the
cache live in ``counters["cache_bytes_served"]`` — never in
``bytes_read`` — so merged snapshots never double-count backend bytes.
An optional contention model charges hits at client-memory speed
(:meth:`~repro.metrics.contention.ContentionModel.cache_hit`), which is
what moves the read-side knee right in ``fdb_hammer --scaling``.
"""

from __future__ import annotations

import threading
import time
from typing import Callable, Iterator, Mapping, Sequence

from ..core.client import FDBClient, WipeReport
from ..core.datahandle import DataHandle, MemoryDataHandle
from ..core.fieldset import FieldResolutionError, FieldSet
from ..core.keys import Key
from ..core.request import Request, as_request
from ..core.schema import Schema
from ..metrics.iostats import IOStats
from .shard import ShardedCache
from .singleflight import SingleFlight

__all__ = ["CacheFDB"]

#: default total byte budget (a dissemination node's RAM slice)
DEFAULT_MAX_BYTES = 256 << 20


class CacheFDB(FDBClient):
    """Read-through sharded field cache with single-flight coalescing
    (see module docstring).

    Parameters: ``max_bytes`` total budget, ``ttl_s`` default entry TTL
    (None = no expiry), ``dataset_ttl`` per-dataset overrides as
    ``[{"match": <MARS request>, "ttl_s": <s>}, ...]`` (first match wins),
    ``shards``/``replicas`` the consistent-hash layout, ``negative_ttl``
    the absence-memo TTL (None = absent fields are never cached — every
    miss for a not-yet-archived field pays a full backend round; set it
    short, e.g. the dissemination poll interval, for workloads that probe
    ahead of the forecast), ``clock`` the TTL clock (injectable for
    tests), ``contention`` an optional
    :class:`~repro.metrics.contention.ContentionModel` charged at memory
    speed per cache-served byte."""

    def __init__(
        self,
        inner: FDBClient,
        *,
        max_bytes: int = DEFAULT_MAX_BYTES,
        ttl_s: float | None = None,
        dataset_ttl: Sequence[Mapping] = (),
        shards: int = 8,
        replicas: int = 32,
        negative_ttl: float | None = None,
        owns_inner: bool = True,
        clock: Callable[[], float] = time.monotonic,
        contention=None,
    ):
        self.inner = inner
        self.schema: Schema = inner.schema
        self._codec_nbits = getattr(inner, "_codec_nbits", type(self)._codec_nbits)
        self._fieldset_batch = inner._fieldset_batch
        self._owns_inner = owns_inner
        self._cache = ShardedCache(
            max_bytes, n_shards=shards, replicas=replicas, clock=clock
        )
        self._ttl_s = None if ttl_s is None else float(ttl_s)
        self._ttl_rules: list[tuple[Request, float | None]] = [
            (as_request(rule["match"]),
             None if rule["ttl_s"] is None else float(rule["ttl_s"]))
            for rule in dataset_ttl
        ]
        self._flight = SingleFlight()
        # request-resolution coalescing + memoisation for partial requests
        self._req_flight = SingleFlight()
        self._req_cache: dict[str, tuple[tuple[Key, ...], float | None]] = {}
        self._req_gen = 0
        # keys archived through this facade but possibly not yet published
        # by the inner tree (AsyncFDB queue, remote coalescing window)
        self._dirty: set[Key] = set()
        self._mu = threading.Lock()  # guards _dirty, _req_cache, _req_gen, _neg
        # negative cache: token -> expiry on the cache clock.  Entries are
        # generation-guarded on store and dropped by every write/move/wipe
        # of the key, so "absent" is never served past the publication that
        # made it wrong (within one process; cross-process it is a TTL).
        self._neg_ttl = None if negative_ttl is None else float(negative_ttl)
        self._neg: dict[str, float] = {}
        self.cache_stats = IOStats("cache")
        self._contention = contention
        # a lifecycle engine below migrates fields between tiers without an
        # archive flowing through this facade: hook its flip so moved keys
        # are invalidated (the bytes are identical, but codec'd tiers may
        # differ, and the negative cache must forget promoted keys)
        from ..lifecycle.engine import LifecycleFDB

        stack, seen = [inner], set()
        while stack:
            node = stack.pop()
            if id(node) in seen:
                continue
            seen.add(id(node))
            if isinstance(node, LifecycleFDB):
                node.add_move_listener(self._note_moved)
            for attr in ("inner", "fdb"):
                sub = getattr(node, attr, None)
                if isinstance(sub, FDBClient):
                    stack.append(sub)
            for attr in ("tiers", "lanes"):
                subs = getattr(node, attr, None)
                if subs:
                    stack.extend(s for s in subs if isinstance(s, FDBClient))

    # ----------------------------------------------------------- key tokens
    @staticmethod
    def _token(key: Key) -> str:
        # sorted, self-describing: Key equality is order-insensitive, so the
        # cache identity must be too (canonical() preserves insertion order)
        return ";".join(f"{k}={v}" for k, v in sorted(key.items()))

    def _ds_token(self, key: Key) -> str:
        return self._token(key.subset(self.schema.dataset_keys))

    def _ttl_for(self, key: Key) -> float | None:
        for match, ttl in self._ttl_rules:
            if key.matches(match):
                return ttl
        return self._ttl_s

    # ------------------------------------------------------- write ordering
    def read_barrier(self, keys: Sequence[Key] | None = None) -> None:
        """The explicit ordering hook between this client's writes and its
        reads: if any of *keys* (all dirty keys when None) was archived
        through this facade but possibly not yet published by the inner
        tree, flush the inner tree first.  Every invalidation-sensitive
        read path calls this, so ``archive -> retrieve`` through a
        ``cache``-over-``async`` composition is read-your-writes without a
        caller ``flush()``.  Reads of clean keys never pay the barrier."""
        with self._mu:
            if not self._dirty:
                return
            if keys is not None and not any(k in self._dirty for k in keys):
                return
        self.flush()

    def _note_write(self, keys: Sequence[Key]) -> None:
        """Write-path invalidation: drop the touched entries (bumping shard
        generations, so racing fills are refused), clear the memoised
        request resolutions, and mark the keys dirty for the barrier."""
        with self._mu:
            self._dirty.update(keys)
            self._req_gen += 1
            self._req_cache.clear()
            for k in keys:
                self._neg.pop(self._token(k), None)
        for k in keys:
            self._cache.invalidate(self._token(k))

    def _note_moved(self, keys: Sequence[Key]) -> None:
        """Migration-path invalidation (lifecycle flip listener): drop the
        moved keys' cached entries, memos and negative entries.  Unlike
        :meth:`_note_write` this does NOT mark keys dirty — the destination
        copy is already flushed and published when the flip happens."""
        with self._mu:
            self._req_gen += 1
            self._req_cache.clear()
            for k in keys:
                self._neg.pop(self._token(k), None)
        for k in keys:
            self._cache.invalidate(self._token(k))

    # ----------------------------------------------------------- write path
    def archive(self, key: Key | Mapping[str, str], data: bytes) -> None:
        key = self._as_key(key)
        self._note_write([key])
        self.inner.archive(key, data)

    def archive_batch(self, items) -> None:
        items = [(self._as_key(k), d) for k, d in items]
        self._note_write([k for k, _ in items])
        self.inner.archive_batch(items)

    def archive_fields(self, keys, fields, *, nbits: int | None = None) -> None:
        # delegate WITHOUT packing here: routing facades below (SelectFDB)
        # must split the batch so each codec tier packs at its own width
        keys = [self._as_key(k) for k in keys]
        self._note_write(keys)
        self.inner.archive_fields(keys, fields, nbits=nbits)

    def flush(self) -> None:
        self.inner.flush()
        with self._mu:
            self._dirty.clear()

    def drain(self) -> None:
        # bytes reached the backend, but deferred-visibility backends may
        # not have published them: keys stay dirty until flush()
        self.inner.drain()

    # ------------------------------------------------------------ read path
    def retrieve_batch(self, keys) -> list[DataHandle | None]:
        keys = [self._as_key(k) for k in keys]
        tr = self._trace
        with tr.span("cache.retrieve_batch") as sp:
            self.read_barrier(keys)
            # dedupe within the batch: one lookup/flight per distinct key
            order: list[tuple[str, Key]] = []
            positions: dict[str, list[int]] = {}
            for i, k in enumerate(keys):
                t = self._token(k)
                if t not in positions:
                    positions[t] = []
                    order.append((t, k))
                positions[t].append(i)

            resolved: dict[str, bytes | None] = {}
            leaders: list[tuple[str, Key, object, int]] = []
            waits: list[tuple[str, object]] = []
            hits = served_b = neg_hits = 0
            for tok, k in order:
                data, status = self._cache.get(tok)
                if status == "hit":
                    hits += 1
                    served_b += len(data)
                    resolved[tok] = data
                    if tr.enabled:
                        with tr.span("cache.hit") as hsp:
                            hsp.set("nbytes", len(data))
                    if self._contention is not None:
                        self._contention.cache_hit(len(data))
                    continue
                if self._neg_ttl is not None:
                    with self._mu:
                        exp = self._neg.get(tok)
                        if exp is not None and self._cache.clock() >= exp:
                            del self._neg[tok]
                            exp = None
                    if exp is not None:
                        # memoised absence: no backend round, no flight
                        neg_hits += 1
                        resolved[tok] = None
                        if tr.enabled:
                            with tr.span("cache.neg_hit"):
                                pass
                        if self._contention is not None:
                            self._contention.cache_hit(0)
                        continue
                flight, is_leader = self._flight.join(tok)
                if is_leader:
                    # snapshot the shard generation BEFORE the fetch: a
                    # write racing this fill bumps it and the insert is
                    # refused (the fetched bytes may predate the write)
                    leaders.append((tok, k, flight, self._cache.generation(tok)))
                else:
                    waits.append((tok, flight))

            backend_b = evicts = evict_b = 0
            if leaders:
                backend_b, evicts, evict_b = self._lead_fetch(leaders, resolved, tr)
            for tok, flight in waits:
                with tr.span("cache.coalesced_wait") as wsp:
                    data = self._flight.wait(flight)
                    if tr.enabled:
                        wsp.set("nbytes", 0 if data is None else len(data))
                resolved[tok] = data
                if data is not None:
                    served_b += len(data)
                    if self._contention is not None:
                        self._contention.cache_hit(len(data))

            self._account(
                hits=hits, misses=len(leaders), coalesced=len(waits),
                served_b=served_b, backend_b=backend_b,
                evicts=evicts, evict_b=evict_b, neg_hits=neg_hits,
            )
            if tr.enabled:
                sp.set("n_keys", len(keys))
                sp.set("hits", hits)
                sp.set("misses", len(leaders))
                sp.set("coalesced", len(waits))

            out: list[DataHandle | None] = [None] * len(keys)
            for tok, _ in order:
                data = resolved[tok]
                if data is None:
                    continue
                for i in positions[tok]:
                    out[i] = MemoryDataHandle(data)
            return out

    def _lead_fetch(self, leaders, resolved, tr) -> tuple[int, int, int]:
        """Pay ONE inner round for all leader keys; publish each flight's
        outcome (errors included — they propagate to followers and are
        never cached) and fill the cache, generation-guarded."""
        fetch_keys = [k for _, k, _, _ in leaders]
        try:
            with tr.span("cache.miss") as msp:
                handles = self.inner.retrieve_batch(fetch_keys)
                if tr.enabled:
                    msp.set("n_keys", len(fetch_keys))
            if len(handles) != len(leaders):
                raise FieldResolutionError(
                    f"inner retrieve_batch returned {len(handles)} handles "
                    f"for {len(leaders)} keys"
                )
        except BaseException as e:
            for tok, _, flight, _ in leaders:
                self._flight.complete(tok, flight, error=e)
            raise
        backend_b = evicts = evict_b = 0
        done = 0
        try:
            for (tok, k, flight, gen), h in zip(leaders, handles):
                if h is None:
                    data = None
                    if self._neg_ttl is not None:
                        # memoise the absence, generation-guarded like a
                        # fill: an archive that raced this fetch bumped the
                        # generation (and purged the token from _neg), so a
                        # stale "absent" is never stored over fresh bytes
                        if self._cache.generation(tok) == gen:
                            with self._mu:
                                self._neg[tok] = self._cache.clock() + self._neg_ttl
                            self.cache_stats.record("cache_neg_store")
                else:
                    try:
                        data = h.read()
                    finally:
                        h.close()
                if data is not None:
                    backend_b += len(data)
                    _, n_ev, ev_b = self._cache.put(
                        tok, data, self._ds_token(k), self._ttl_for(k),
                        expected_gen=gen,
                    )
                    evicts += n_ev
                    evict_b += ev_b
                self._flight.complete(tok, flight, value=data)
                done += 1
                resolved[tok] = data
        except BaseException as e:
            # a failed handle read must not strand the LATER leaders'
            # followers: every still-open flight observes the error
            for tok, _, flight, _ in leaders[done:]:
                self._flight.complete(tok, flight, error=e)
            raise
        if evicts and tr.enabled:
            with tr.span("cache.evict") as esp:
                esp.set("n_entries", evicts)
                esp.set("nbytes", evict_b)
        return backend_b, evicts, evict_b

    # ------------------------------------------------- request-level reads
    def retrieve_many(self, request) -> FieldSet:
        tr = self._trace
        with tr.span("cache.retrieve_many") as sp:
            req = self._validated_request(request)
            if req.is_exact(self.schema):
                keys = req.expand(self.schema)
            else:
                keys = self._resolve_keys(req)
            if tr.enabled:
                sp.set("n_keys", len(keys))
            return FieldSet(keys, self._many_fetch, batch_size=self._fieldset_batch)

    def _resolve_keys(self, req: Request) -> list[Key]:
        """Partial-request resolution with memoisation + single-flight: N
        concurrent identical MARS requests cost one catalogue listing, and
        the resolved key list is cached (default TTL) until any write
        invalidates it."""
        text = req.format()
        with self._mu:
            dirty = bool(self._dirty)
        if dirty:
            # an unpublished archive may extend this listing: publish first
            self.flush()
        with self._mu:
            hit = self._req_cache.get(text)
            if hit is not None:
                cached, expires = hit
                if expires is None or self._cache.clock() < expires:
                    self.cache_stats.record("cache_list_hit")
                    return list(cached)
                del self._req_cache[text]
        flight, is_leader = self._req_flight.join(text)
        if not is_leader:
            self.cache_stats.record("cache_list_coalesced")
            return list(self._req_flight.wait(flight))
        try:
            with self._mu:
                gen = self._req_gen
            keys = tuple(e.key for e in self._inner_list(req))
        except BaseException as e:
            self._req_flight.complete(text, flight, error=e)
            raise
        with self._mu:
            if self._req_gen == gen:  # no write raced the listing
                expires = (
                    None if self._ttl_s is None
                    else self._cache.clock() + self._ttl_s
                )
                self._req_cache[text] = (keys, expires)
        self.cache_stats.record("cache_list_fill")
        self._req_flight.complete(text, flight, value=keys)
        return list(keys)

    def _inner_list(self, request: Request):
        return getattr(self.inner, "_list", self.inner.list)(request)

    def _list(self, request: Request) -> Iterator:
        return self._inner_list(request)

    # ------------------------------------------------------------ wipe path
    def _wipe_dataset(self, dataset_key: Key, entries=None) -> WipeReport:
        report = self.inner._wipe_dataset(dataset_key, entries)
        # invalidate at the granularity the report names: whole datasets
        # (base wipe() calls this once per matched dataset key)
        self._cache.invalidate_dataset(self._ds_token(dataset_key))
        with self._mu:
            self._req_gen += 1
            self._req_cache.clear()
            # negative entries are keyed by full token (cheap to clear,
            # expensive to filter by dataset): drop them all — re-probing an
            # absent field once per wipe is the conservative trade
            self._neg.clear()
        return report

    # ------------------------------------------------------------ telemetry
    def _account(self, *, hits, misses, coalesced, served_b, backend_b,
                 evicts, evict_b, neg_hits=0) -> None:
        st = self.cache_stats
        with st.lock:
            if hits:
                st.ops["cache_hit"] += hits
            if neg_hits:
                st.ops["cache_neg_hit"] += neg_hits
            if misses:
                st.ops["cache_miss"] += misses
            if coalesced:
                st.ops["cache_coalesced_wait"] += coalesced
            if evicts:
                st.ops["cache_evict"] += evicts
            # bytes served without a backend round vs bytes the backend
            # actually moved for fills — deliberately NOT bytes_read, which
            # the inner sinks already account (no double-counting on merge)
            if served_b:
                st.counters["cache_bytes_served"] += served_b
            if backend_b:
                st.counters["cache_bytes_backend"] += backend_b
            if evict_b:
                st.counters["cache_bytes_evicted"] += evict_b

    def io_stats(self) -> list:
        return list(self.inner.io_stats()) + [self.cache_stats] + self._codec_sinks()

    def cache_snapshot(self) -> dict:
        """The cache-tier scorecard: hit/miss/coalesced counts, hit rate
        (cache-served lookups over all lookups) and the dissemination win —
        bytes served per backend byte."""
        with self.cache_stats.lock:
            ops = dict(self.cache_stats.ops)
            counters = dict(self.cache_stats.counters)
        hits = ops.get("cache_hit", 0)
        misses = ops.get("cache_miss", 0)
        coalesced = ops.get("cache_coalesced_wait", 0)
        served = counters.get("cache_bytes_served", 0)
        backend = counters.get("cache_bytes_backend", 0)
        lookups = hits + misses + coalesced
        with self._mu:
            neg_entries = len(self._neg)
        return {
            "hits": hits,
            "misses": misses,
            "coalesced": coalesced,
            "evictions": ops.get("cache_evict", 0),
            "hit_rate": (hits + coalesced) / lookups if lookups else 0.0,
            "neg_hits": ops.get("cache_neg_hit", 0),
            "neg_stores": ops.get("cache_neg_store", 0),
            "neg_entries": neg_entries,
            "bytes_served": served,
            "bytes_backend": backend,
            "bytes_served_per_backend_byte": (
                (served + backend) / backend if backend else 0.0
            ),
            "entries": len(self._cache),
            "bytes_cached": self._cache.nbytes,
        }

    # ------------------------------------------------------------ lifecycle
    def invalidate_all(self) -> int:
        """Drop every cached entry and memoised resolution (e.g. when an
        EXTERNAL writer shares the inner tree); returns entries dropped."""
        with self._mu:
            self._req_gen += 1
            self._req_cache.clear()
            self._neg.clear()
        return self._cache.clear()

    def close(self) -> None:
        if self._owns_inner:
            self.inner.close()
        else:
            self.inner.flush()
        with self._mu:
            self._dirty.clear()
            self._req_cache.clear()
            self._neg.clear()
        self._cache.clear()

    def __repr__(self) -> str:
        return (
            f"CacheFDB(max_bytes={sum(s.max_bytes for s in self._cache.shards)}, "
            f"shards={len(self._cache.shards)}, inner={self.inner!r})"
        )

"""Trip-count-exact roofline probing.

XLA's cost_analysis counts a while-loop body ONCE regardless of trip count,
so a scanned 60-layer model under-reports FLOPs/bytes/collectives by ~60×.
The probes lower two small UNROLLED variants of the same cell —
``a`` layers and ``2a`` layers (a = hybrid period for zamba2, else 1) —
measure exact totals, and reconstruct:

    per_layer = (U_2a − U_a) / a
    total(L)  = (U_a − a·per_layer) + L·per_layer

This is exact for homogeneous stacks; for the hybrid the shared block's
contribution is averaged into per_layer (L/a applications assumed — 13.5 vs
the true 13 for 81 layers, <4% high on the shared block only; noted in
EXPERIMENTS.md).  The loss CE chunking is Python-unrolled in the model, so
it is fully visible to both probes and lands in the non-scan constant.
"""

from __future__ import annotations

import dataclasses

import jax

from repro.configs.base import ModelConfig, ShapeConfig
from .analysis import parse_collectives

__all__ = ["probe_corrected_costs"]

_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")


def _probe_cfg(cfg: ModelConfig, n_layers: int) -> ModelConfig:
    kw: dict = {"n_layers": n_layers, "scan_layers": False}
    if cfg.is_encoder_decoder:
        kw["encoder_layers"] = n_layers
    return dataclasses.replace(cfg, **kw)


def _measure(cfg: ModelConfig, mesh, shape: ShapeConfig, hp=None) -> dict:
    from repro.launch.steps import build_cell

    fn, args, ins, outs, donate = build_cell(cfg, mesh, shape, hp=hp)
    with mesh:
        compiled = (
            jax.jit(fn, in_shardings=ins, out_shardings=outs, donate_argnums=donate)
            .lower(*args)
            .compile()
        )
    cost = compiled.cost_analysis()
    coll = parse_collectives(compiled.as_text())
    out = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll_total": float(coll["total_bytes"]),
    }
    for op in _COLL_OPS:
        out[f"coll_{op}"] = float(coll["bytes_by_op"].get(op, 0.0))
    return out


def probe_corrected_costs(cfg: ModelConfig, mesh, shape: ShapeConfig, hp=None) -> dict:
    """Returns corrected totals for the REAL layer count of `cfg`."""
    a = cfg.hybrid_attn_every if cfg.family == "hybrid" and cfg.hybrid_attn_every else 1
    u_a = _measure(_probe_cfg(cfg, a), mesh, shape, hp=hp)
    u_2a = _measure(_probe_cfg(cfg, 2 * a), mesh, shape, hp=hp)
    L = cfg.n_layers
    corrected = {}
    for k in u_a:
        per_layer = (u_2a[k] - u_a[k]) / a
        non_scan = u_a[k] - a * per_layer
        corrected[k] = max(0.0, non_scan + L * per_layer)
    corrected["probe_a"] = a
    corrected["probe_raw"] = {"U_a": u_a, "U_2a": u_2a}
    return corrected

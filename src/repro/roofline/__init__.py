from .analysis import HW, RooflineReport, model_flops_for, parse_collectives, roofline

__all__ = ["HW", "RooflineReport", "model_flops_for", "parse_collectives", "roofline"]

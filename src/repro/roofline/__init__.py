from .analysis import HW, RooflineReport, model_flops_for, parse_collectives, roofline
from .codec import CodecRoofline, codec_roofline, ridge_intensity

__all__ = [
    "HW",
    "RooflineReport",
    "model_flops_for",
    "parse_collectives",
    "roofline",
    "CodecRoofline",
    "codec_roofline",
    "ridge_intensity",
]

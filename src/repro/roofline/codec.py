"""Roofline placement of the GRIB pack/unpack kernels.

The codec kernels are streaming quantisers: per element, ``grib_pack`` does
a subtract, a multiply, a round and a clamp (~4 flops) against 4 B read +
``nbits/8`` B written, and ``grib_unpack`` a multiply-add (~2 flops) against
``nbits/8`` B read + 4 B written.  Their arithmetic intensity is therefore
well under 1 flop/byte, orders of magnitude below the HBM ridge point
(``peak_flops / hbm_bw`` ≈ 240 flop/B on the v5e-class model in
:mod:`repro.roofline.analysis`) — the codec is memory-bound, and fusing it
onto the archive path costs one extra HBM pass, never compute.

These analytic probes let the benchmarks report where a codec configuration
sits on the roofline without a compiled artifact: the kernels are too simple
for HLO cost analysis to say anything the closed form doesn't.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..kernels.grib_pack.ops import payload_dtype
from .analysis import HW

__all__ = ["CodecRoofline", "codec_roofline", "ridge_intensity"]

# per-element flop model (see module docstring)
_PACK_FLOPS_PER_ELEM = 4.0    # subtract, scale, round, clamp
_UNPACK_FLOPS_PER_ELEM = 2.0  # multiply-add


def ridge_intensity(hw: dict | None = None) -> float:
    """The HBM ridge point in flop/byte — kernels below it are memory-bound."""
    hw = HW if hw is None else hw
    return hw["peak_flops"] / hw["hbm_bw"]


@dataclass
class CodecRoofline:
    kind: str                 # "pack" | "unpack"
    nbits: int
    n_elems: int
    flops: float
    hbm_bytes: float          # raw bytes + code bytes + ref/scale traffic
    intensity: float          # flop/byte
    ridge: float              # HBM ridge point of the HW model
    bound: str                # "memory" | "compute"
    compute_s: float          # analytic lower-bound times on the HW model
    memory_s: float

    def as_dict(self) -> dict:
        return {k: getattr(self, k) for k in self.__dataclass_fields__}


def codec_roofline(
    kind: str,
    shape: tuple[int, ...],
    *,
    nbits: int = 16,
    hw: dict | None = None,
) -> CodecRoofline:
    """Analytic roofline terms for one codec launch over fields of *shape*.

    ``shape`` is ``(F, H, W)`` (or any shape; elements are what matter).
    Byte traffic counts the float32 side once and the packed side once at
    the CONTAINER width (24-bit codes ride uint32 lanes, same honest
    convention as the wire format).
    """
    if kind not in ("pack", "unpack"):
        raise ValueError(f"kind must be 'pack' or 'unpack', got {kind!r}")
    hw = HW if hw is None else hw
    n = int(np.prod(shape)) if shape else 0
    code_itemsize = payload_dtype(nbits).itemsize
    if kind == "pack":
        flops = _PACK_FLOPS_PER_ELEM * n
        # read f32 twice (min/max reduction pass + quantise pass), write codes
        hbm = n * (2 * 4 + code_itemsize)
    else:
        flops = _UNPACK_FLOPS_PER_ELEM * n
        hbm = n * (code_itemsize + 4)
    intensity = flops / hbm if hbm else 0.0
    ridge = ridge_intensity(hw)
    compute_s = flops / hw["peak_flops"]
    memory_s = hbm / hw["hbm_bw"]
    return CodecRoofline(
        kind=kind, nbits=nbits, n_elems=n,
        flops=flops, hbm_bytes=float(hbm),
        intensity=intensity, ridge=ridge,
        bound="memory" if intensity < ridge else "compute",
        compute_s=compute_s, memory_s=memory_s,
    )

"""Roofline terms from a compiled dry-run artifact.

Hardware model (TPU v5e class, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.

    compute_s    = HLO_FLOPs / (chips · peak)
    memory_s     = HLO_bytes / (chips · hbm_bw)
    collective_s = collective_wire_bytes / (chips · link_bw)

cost_analysis() on the partitioned module reports per-device FLOPs/bytes, so
per-device terms equal the global formula (both numerator and denominator
scale by `chips`).  Collective bytes are parsed from the compiled
(post-GSPMD) HLO text with a symbol table so operand shapes are exact;
wire-byte convention per op (ring algorithms):

    all-reduce         2 × operand bytes
    all-gather         result bytes
    reduce-scatter     operand bytes
    all-to-all         operand bytes
    collective-permute operand bytes
"""

from __future__ import annotations

import re
from collections import Counter
from dataclasses import dataclass, field

__all__ = ["HW", "parse_collectives", "roofline", "RooflineReport"]

HW = {
    "peak_flops": 197e12,   # bf16 / chip
    "hbm_bw": 819e9,        # B/s / chip
    "ici_bw": 50e9,         # B/s / link
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(pred|s8|u8|s16|u16|bf16|f16|f32|s32|u32|s64|u64|f64|c64|c128)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s([\w\-]+)\(")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Per-device wire bytes by collective op, from partitioned HLO text."""
    # symbol table: instruction name -> result bytes
    sizes: dict[str, int] = {}
    lines = hlo_text.splitlines()
    for ln in lines:
        m = _DEF_RE.match(ln)
        if m:
            name, type_str, _op = m.groups()
            sizes[name] = _type_bytes(type_str)

    wire = Counter()
    counts = Counter()
    for ln in lines:
        m = _DEF_RE.match(ln)
        if not m:
            continue
        name, type_str, op = m.groups()
        base = None
        for c in _COLLECTIVES:
            if op == c or op == c + "-start":
                base = c
                break
        if base is None or op.endswith("-done"):
            continue
        # operand list: names inside the outermost parens
        paren = ln[ln.index(op) + len(op):]
        operand_names = re.findall(r"%?([\w.\-]+)(?:,|\))", paren.split("），")[0])
        operand_bytes = sum(sizes.get(n, 0) for n in operand_names if n in sizes)
        result_bytes = _type_bytes(type_str)
        if operand_bytes == 0:
            operand_bytes = result_bytes
        if base == "all-reduce":
            b = 2 * operand_bytes
        elif base == "all-gather":
            b = result_bytes
        else:
            b = operand_bytes
        wire[base] += b
        counts[base] += 1
    return {"bytes_by_op": dict(wire), "counts": dict(counts), "total_bytes": sum(wire.values())}


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float            # per device
    hlo_bytes: float            # per device
    collective_bytes: float     # per device wire bytes
    model_flops: float          # global useful FLOPs (6ND / 2ND)
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str = ""
    useful_ratio: float = 0.0
    collectives: dict = field(default_factory=dict)

    def as_dict(self) -> dict:
        return {k: getattr(self, k) for k in self.__dataclass_fields__}


def roofline(
    *,
    arch: str,
    shape: str,
    mesh: str,
    chips: int,
    cost: dict,
    collectives: dict,
    model_flops: float,
) -> RooflineReport:
    flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    coll_bytes = float(collectives.get("total_bytes", 0.0))
    compute_s = flops / HW["peak_flops"]
    memory_s = raw_bytes / HW["hbm_bw"]
    collective_s = coll_bytes / HW["ici_bw"]
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    useful = model_flops / max(flops * chips, 1.0)
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh, chips=chips,
        hlo_flops=flops, hlo_bytes=raw_bytes, collective_bytes=coll_bytes,
        model_flops=model_flops,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck, useful_ratio=useful, collectives=collectives,
    )


def model_flops_for(cfg, shape) -> float:
    """Useful FLOPs per step: 6·N_active·tokens (train), 2·N_active·tokens (serve)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per sequence

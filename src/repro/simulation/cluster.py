"""Bottleneck-model cluster simulator: FDB workloads on Lustre vs DAOS.

A laptop cannot host 16 storage nodes + 32 clients, so the scaling figures
(paper Figs 3/4/6) are reproduced by replaying the backends' per-field
operation recipes through the calibrated cost model
(:mod:`repro.core.costmodel`) and a capacity/latency bottleneck analysis:

    phase_time = max( server_bandwidth_time,
                      client_bandwidth_time,
                      mds_time               (Lustre only),
                      per_process_serial_time )

Contention mechanics — the paper's core claim, §2:

- **Lustre**: a reader crossing a writer's cached write locks triggers a
  blocking AST + lock round-trip per conflicting extent; the conflict rate
  per process grows with the number of opposing processes sharing the
  servers.  MDS ops serialise on a single metadata node.
- **DAOS**: MVCC resolves contention server-side; readers/writers never
  exchange locks.  Cost of contention is only target queueing (already in
  the bandwidth term).  Per-op TCP round-trips are *higher* than Lustre's
  PSM2 — DAOS wins under contention despite the slower network, exactly as
  measured in the paper.

The test system mirrors NEXTGenIO (§4.1): dual-socket nodes, 2 network
rails, ~6 GiB/s effective per-socket storage bandwidth, 12.5 GiB/s NICs.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.core.costmodel import DEFAULT_DAOS, DEFAULT_LUSTRE, DaosCosts, LustreCosts

__all__ = ["Workload", "simulate", "SimResult"]

GiB = float(1 << 30)

#: client-side op pipelining (outstanding requests per process)
PIPELINE = 4.0


@dataclass(frozen=True)
class Workload:
    n_server_nodes: int
    n_client_nodes: int
    procs_per_client: int
    fields_per_proc: int
    field_size: int = 1 << 20
    mode: str = "write"              # 'write' | 'read'
    contention: bool = False         # opposing readers+writers active
    n_opposing_procs: int = 0        # procs on the other side (for conflicts)
    flush_every: int = 200           # fields between flushes (steps)

    @property
    def n_procs(self) -> int:
        return self.n_client_nodes * self.procs_per_client

    @property
    def total_bytes(self) -> int:
        return self.n_procs * self.fields_per_proc * self.field_size


@dataclass(frozen=True)
class SimResult:
    bandwidth_Bps: float
    phase_time_s: float
    terms: dict

    @property
    def bandwidth_GiBps(self) -> float:
        return self.bandwidth_Bps / GiB


def _daos_per_field_latency(w: Workload, c: DaosCosts) -> float:
    """Serial client-visible latency per field (excluding bandwidth)."""
    if w.mode == "write":
        # array open_with_attrs + array_write + catalogue kv_put (+ axis puts
        # amortised) ; OID allocation amortised over the cached range
        ops = [c.rtt_s + c.array_op_s, c.rtt_s + c.array_op_s, c.rtt_s + c.kv_op_s]
        ops.append((c.rtt_s + c.kv_op_s) / 64.0)  # amortised alloc/axis
    else:
        # catalogue kv_get (cached dataset/colloc handles) + array_read;
        # no get_size round trip (length rides in the location descriptor)
        ops = [c.rtt_s + c.kv_op_s, c.rtt_s + c.array_op_s]
    return sum(ops) / PIPELINE


def _lustre_per_field_latency(w: Workload, c: LustreCosts) -> float:
    if w.mode == "write":
        # buffered append to the private stream + amortised TOC append at
        # flush; own-extent lock is cached (one enqueue per stream chunk)
        ops = [c.rtt_s, c.lock_rtt_s / 32.0]
        ops.append((c.mds_op_s + c.lock_rtt_s) / w.flush_every)  # segment+TOC
    else:
        # locate via cached TOC/index (amortised) + read: read lock enqueue
        ops = [c.lock_rtt_s, c.rtt_s]
        ops.append(c.mds_op_s / 64.0)  # occasional open/stat
    return sum(ops) / PIPELINE


def simulate(backend: str, w: Workload, *, lustre: LustreCosts = DEFAULT_LUSTRE, daos: DaosCosts = DEFAULT_DAOS) -> SimResult:
    opposing_per_server = (
        w.n_opposing_procs / max(1, w.n_server_nodes) if w.contention else 0.0
    )
    if backend == "daos":
        per_node_bw = 2 * daos.engine_bw_Bps  # 2 engines (sockets) per node
        if w.contention:
            per_node_bw *= daos.rw_interference  # log-structured: mild mixing cost
        client_bw = min(daos.client_bw_Bps, w.procs_per_client * daos.per_proc_bw_Bps)
        per_field = _daos_per_field_latency(w, daos)
        # index KV ops queue at their target engine (metadata spread over ALL
        # engines — no dedicated MDS)
        ops_per_field = 2.0 if w.mode == "write" else 1.0
        total_kv_ops = w.n_procs * w.fields_per_proc * ops_per_field
        mds_time = total_kv_ops / (2 * w.n_server_nodes * daos.kv_op_rate)
        conflict_time = 0.0  # MVCC: server-side, lockless
    elif backend == "lustre":
        per_node_bw = 2 * lustre.ost_bw_Bps
        if w.mode == "read":
            # data scattered across per-writer streams: seeky reads (§5.3 b)
            per_node_bw *= lustre.read_bw_derate
        if w.contention:
            # mixed r/w interference: readers invalidate writers' cached
            # write locks; OST queue alternates flush/read
            per_node_bw /= 1.0 + opposing_per_server / lustre.rw_interference_k
        client_bw = min(lustre.client_bw_Bps, w.procs_per_client * lustre.per_proc_bw_Bps, lustre.node_protocol_cap_Bps)
        per_field = _lustre_per_field_latency(w, lustre)
        # one MDS node total: segment/TOC/open ops serialise there.  While
        # writers append, every reader retrieve re-polls the TOC (stat +
        # read-lock enqueue) — the dominant metadata load under contention.
        tail_rate = (
            lustre.toc_tail_rate_contended
            if (w.contention and w.mode == "read") or (w.contention and w.n_opposing_procs)
            else lustre.toc_tail_rate_quiet
        )
        mds_ops = w.n_procs * (
            w.fields_per_proc * tail_rate
            + w.fields_per_proc / 64.0
            + (w.fields_per_proc / w.flush_every) * 2.0
            + 2.0
        )
        mds_time = mds_ops * lustre.mds_op_s
        # lock conflicts: blocking ASTs per conflicting extent
        if w.contention and w.n_opposing_procs:
            conflict_rate = min(1.0, lustre.conflict_base * opposing_per_server / 16.0)
            per_conflict = lustre.lock_cancel_s + lustre.lock_rtt_s
            conflict_time = w.fields_per_proc * conflict_rate * per_conflict
        else:
            conflict_time = 0.0
    else:
        raise ValueError(backend)

    server_time = w.total_bytes / (w.n_server_nodes * per_node_bw)
    client_time = w.total_bytes / (w.n_client_nodes * client_bw)
    serial_time = w.fields_per_proc * per_field + conflict_time
    startup = 0.5 if backend == "daos" else 0.3  # pool/container vs mount overheads

    phase = max(server_time, client_time, mds_time, serial_time) + startup
    terms = {
        "server_bw_s": server_time,
        "client_bw_s": client_time,
        "mds_s": mds_time,
        "serial_s": serial_time,
        "conflict_s": conflict_time,
        "startup_s": startup,
    }
    return SimResult(bandwidth_Bps=w.total_bytes / phase, phase_time_s=phase, terms=terms)

from .cluster import SimResult, Workload, simulate

__all__ = ["SimResult", "Workload", "simulate"]

from .pipeline import PrefetchPipeline, SyntheticLM

__all__ = ["PrefetchPipeline", "SyntheticLM"]

"""Deterministic sharded data pipeline with straggler mitigation.

Determinism-by-step: ``batch_for_step(step)`` is a pure function of
(seed, step, host shard), so a restart replays exactly — the data plane
needs no checkpoint beyond the step counter.

Straggler mitigation: a pool of reader threads pulls *work items* (shard
indices of the upcoming steps) from a shared deque — a slow reader never
blocks the step loop as long as any reader keeps up (work stealing), and
prefetch depth bounds memory.  This mirrors the paper's observation that
70% of produced data is consumed while the producers are still running:
the consumer side must be decoupled from individual producer latency.
"""

from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass

import numpy as np

__all__ = ["SyntheticLM", "PrefetchPipeline"]


@dataclass(frozen=True)
class SyntheticLM:
    """Deterministic synthetic token stream (zipfian-ish)."""

    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_index: int = 0
    n_hosts: int = 1

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts

    def batch_for_step(self, step: int) -> dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.host_index])
        )
        # zipf-flavored ids clipped to vocab, cheap + deterministic
        z = rng.zipf(1.3, size=(self.host_batch, self.seq_len + 1))
        toks = (z % (self.vocab - 2)).astype(np.int32) + 1
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


class PrefetchPipeline:
    """Work-stealing prefetcher over any `batch_for_step` source."""

    def __init__(self, source, *, n_readers: int = 2, depth: int = 4,
                 delay_injector=None):
        self.source = source
        self.depth = depth
        self._work: queue.Queue[int] = queue.Queue()
        self._done: dict[int, dict] = {}
        self._cv = threading.Condition()
        self._stop = threading.Event()
        self._delay = delay_injector  # tests: fn(step) -> seconds, simulates stragglers
        self._next_to_schedule = 0
        self._readers = [
            threading.Thread(target=self._reader, name=f"reader-{i}", daemon=True)
            for i in range(n_readers)
        ]
        for _ in range(depth):
            self._work.put(self._next_to_schedule)
            self._next_to_schedule += 1
        for t in self._readers:
            t.start()

    def _reader(self) -> None:
        while not self._stop.is_set():
            try:
                step = self._work.get(timeout=0.1)
            except queue.Empty:
                continue
            if self._delay:
                time.sleep(self._delay(step))
            batch = self.source.batch_for_step(step)
            with self._cv:
                self._done[step] = batch
                self._cv.notify_all()

    def get(self, step: int, timeout: float = 60.0) -> dict:
        """Blocks until `step`'s batch is ready (any reader may produce it)."""
        deadline = time.monotonic() + timeout
        with self._cv:
            while step not in self._done:
                if time.monotonic() > deadline:
                    raise TimeoutError(f"batch for step {step} not produced in time")
                self._cv.wait(0.05)
            batch = self._done.pop(step)
        # keep the window full
        self._work.put(self._next_to_schedule)
        self._next_to_schedule += 1
        return batch

    def reset_to(self, step: int) -> None:
        """After restart: drop prefetched work and refill from `step`."""
        with self._cv:
            self._done.clear()
        while not self._work.empty():
            try:
                self._work.get_nowait()
            except queue.Empty:
                break
        self._next_to_schedule = step
        for _ in range(self.depth):
            self._work.put(self._next_to_schedule)
            self._next_to_schedule += 1

    def close(self) -> None:
        self._stop.set()

from .synthetic import FIELD_BASE, synthetic_field

__all__ = ["FIELD_BASE", "synthetic_field"]

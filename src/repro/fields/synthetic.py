"""Synthetic global weather fields for the NWP-driver examples/benchmarks.

Cheap spectral synthesis: a few random low-order zonal/meridional harmonics
plus noise — smooth, bounded 2-D fields resembling global analysis slices,
deterministic per (param, member, step).
"""

from __future__ import annotations

import numpy as np

__all__ = ["synthetic_field", "FIELD_BASE"]

FIELD_BASE = {
    "2t": (288.0, 15.0),    # 2m temperature [K]
    "10u": (0.0, 8.0),      # 10m U wind [m/s]
    "10v": (0.0, 8.0),
    "msl": (101325.0, 800.0),  # mean sea-level pressure [Pa]
    "t": (250.0, 20.0),
    "u": (0.0, 12.0),
    "v": (0.0, 12.0),
    "q": (0.004, 0.002),    # specific humidity [kg/kg]
}


def synthetic_field(
    param: str = "2t",
    member: int = 0,
    step: int = 0,
    *,
    nlat: int = 181,
    nlon: int = 360,
    n_modes: int = 6,
) -> np.ndarray:
    """(nlat, nlon) float32 field, deterministic in (param, member, step)."""
    base, scale = FIELD_BASE.get(param, (0.0, 1.0))
    seed = abs(hash((param, member, step))) % (2**31)
    rng = np.random.default_rng(seed)
    lat = np.linspace(-np.pi / 2, np.pi / 2, nlat)[:, None]
    lon = np.linspace(0, 2 * np.pi, nlon, endpoint=False)[None, :]
    f = np.zeros((nlat, nlon))
    for _ in range(n_modes):
        k = rng.integers(1, 6)
        m = rng.integers(0, 5)
        amp = rng.normal() / (1 + k + m)
        phase = rng.uniform(0, 2 * np.pi)
        f += amp * np.cos(m * lon + phase) * np.cos(lat) ** k
    # gentle temporal evolution so consecutive steps correlate
    f = f + 0.1 * step * np.cos(lon + 0.3 * step) * np.cos(lat)
    f = f / max(np.abs(f).std(), 1e-9)
    return (base + scale * f).astype(np.float32)
